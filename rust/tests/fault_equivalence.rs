//! Pins the chaos-layer contracts (see `device/fault.rs` module docs):
//!
//! * with no fault plan armed — or a no-op plan armed, or a plan
//!   armed and cleared — every substrate path is bit-for-bit identical
//!   to a never-armed build, including the RNG stream position;
//! * with faults armed, results are deterministic for a fixed plan
//!   seed and independent of the worker-thread count and schedule;
//! * pulse accounting is unchanged by the fault mask (stuck cells
//!   still receive and count pulses).

use analog_rider::device::fault::{FaultFamily, FaultPlan};
use analog_rider::device::{presets, DeviceArray, TileGeometry, TiledArray};
use analog_rider::util::rng::Rng;

const ROWS: usize = 48;
const COLS: usize = 40;

fn bare(seed: u64) -> DeviceArray {
    DeviceArray::sample(
        ROWS,
        COLS,
        &presets::preset("om").unwrap(),
        0.4,
        0.2,
        0.1,
        &mut Rng::from_seed(seed),
    )
}

fn tiled(seed: u64) -> TiledArray {
    TiledArray::sample(
        70,
        50,
        TileGeometry::new(16, 16).unwrap(),
        &presets::preset("om").unwrap(),
        0.3,
        0.1,
        0.1,
        &mut Rng::from_seed(seed),
    )
}

/// Every mutating path once, from a caller-owned RNG; returns the
/// final weights, the pulse count and the RNG's next draw (stream
/// position probe).
fn exercise(arr: &mut DeviceArray, rng_seed: u64) -> (Vec<f32>, u64, u64) {
    let mut rng = Rng::from_seed(rng_seed);
    let dw: Vec<f32> = (0..arr.len())
        .map(|i| ((i % 7) as f32 - 3.0) * 0.02)
        .collect();
    for _ in 0..3 {
        arr.analog_update(&dw, &mut rng);
    }
    arr.analog_update_det(&dw);
    arr.pulse_all(true, &mut rng);
    arr.pulse_all_random(&mut rng);
    let target = vec![0.1f32; arr.len()];
    arr.program(&target, &mut rng);
    (arr.w.clone(), arr.pulse_count, rng.next_u64())
}

#[test]
fn disarmed_noop_and_cleared_are_bit_identical() {
    let baseline = exercise(&mut bare(21), 101);

    // a no-op plan armed: the mask is Some(empty), the hot-path branch
    // is taken, and nothing may change
    let mut noop = bare(21);
    FaultPlan::none(7).arm_array(&mut noop, 0);
    assert!(noop.fault_state().unwrap().is_empty());
    assert_eq!(exercise(&mut noop, 101), baseline);

    // a real plan armed on a *fresh* copy and cleared before any use:
    // arming snaps the stuck pins, so clear must come before exercise
    // on yet another fresh copy to prove clear_faults removes the hook
    let mut cleared = bare(21);
    FaultPlan::none(9).arm_array(&mut cleared, 0);
    cleared.clear_faults();
    assert!(cleared.fault_state().is_none());
    assert_eq!(exercise(&mut cleared, 101), baseline);
}

#[test]
fn noop_plan_keeps_tiled_fanout_bit_identical() {
    let base = tiled(31);
    let dw: Vec<f32> = (0..70 * 50)
        .map(|i| ((i % 11) as f32 - 5.0) * 0.01)
        .collect();
    let run = |mut arr: TiledArray, workers: usize| {
        arr.set_parallel(workers > 0);
        arr.set_workers(workers);
        let mut rng = Rng::from_seed(77);
        for _ in 0..3 {
            arr.analog_update(&dw, &mut rng);
        }
        arr.pulse_all_random(&mut rng);
        let noisy = arr.read(0.02, &mut rng);
        (noisy, arr.pulse_count(), rng.next_u64())
    };
    let clean = run(base.clone(), 0);
    for workers in [1usize, 2, 4, 64] {
        let mut armed = base.clone();
        armed.arm_faults(&FaultPlan::none(5));
        assert!(armed.faulty_tiles().is_empty());
        assert_eq!(armed.faulty_cells(), 0);
        assert_eq!(run(armed, workers), clean, "workers = {workers}");
    }
}

#[test]
fn armed_faults_are_deterministic_and_schedule_independent() {
    let plan = FaultPlan {
        drift_rate: 0.2,
        drift_step: 0.05,
        ..FaultPlan::of(13, FaultFamily::StuckAtBound, 0.05)
    };
    let base = {
        let mut a = tiled(41);
        a.arm_faults(&plan);
        a
    };
    assert!(!base.faulty_tiles().is_empty(), "plan must fault some tiles");
    assert!(base.faulty_cells() > 0);
    let dw: Vec<f32> = (0..70 * 50)
        .map(|i| ((i % 9) as f32 - 4.0) * 0.01)
        .collect();
    let run = |mut arr: TiledArray, parallel: bool, workers: usize| {
        arr.set_parallel(parallel);
        arr.set_workers(workers);
        let mut rng = Rng::from_seed(55);
        for _ in 0..4 {
            arr.analog_update(&dw, &mut rng);
        }
        arr.pulse_all_random(&mut rng);
        let mut w = vec![0.0f32; arr.len()];
        arr.read_into(0.0, &mut Rng::from_seed(0), &mut w);
        (w, arr.pulse_count())
    };
    let serial = run(base.clone(), false, 0);
    // same plan, fresh compile: bit-identical (determinism)
    let again = {
        let mut a = tiled(41);
        a.arm_faults(&plan);
        run(a, false, 0)
    };
    assert_eq!(again, serial);
    // any worker count: bit-identical (schedule independence)
    for workers in [1usize, 2, 4, 64] {
        assert_eq!(run(base.clone(), true, workers), serial, "workers = {workers}");
    }
}

#[test]
fn armed_faults_do_not_change_pulse_accounting() {
    let mut clean = bare(61);
    let mut faulty = bare(61);
    FaultPlan::of(3, FaultFamily::StuckAtBound, 0.3).arm_array(&mut faulty, 0);
    let dw = vec![0.03f32; ROWS * COLS];
    let mut rc = Rng::from_seed(5);
    let mut rf = Rng::from_seed(5);
    for _ in 0..3 {
        clean.analog_update(&dw, &mut rc);
        faulty.analog_update(&dw, &mut rf);
    }
    // stuck cells still receive (and count) pulses
    assert_eq!(clean.pulse_count, faulty.pulse_count);
    // ... and the two streams stay in lockstep
    assert_eq!(rc.next_u64(), rf.next_u64());
}

#[test]
fn single_tile_armed_grid_matches_bare_array() {
    // tile 0 compiles from Rng::new(seed, 0) — the same sub-stream
    // `arm_array(arr, 0)` uses — so a 1×1 grid stays bit-identical to
    // the bare array even with faults armed
    let preset = presets::preset("om").unwrap();
    let geom = TileGeometry::new(64, 64).unwrap();
    let mut grid = TiledArray::sample(ROWS, COLS, geom, &preset, 0.4, 0.2, 0.1, &mut Rng::from_seed(71));
    assert_eq!(grid.grid_shape(), (1, 1));
    let mut flat =
        DeviceArray::sample(ROWS, COLS, &preset, 0.4, 0.2, 0.1, &mut Rng::from_seed(71));
    let plan = FaultPlan::of(17, FaultFamily::StuckAtSp, 0.1);
    grid.arm_faults(&plan);
    plan.arm_array(&mut flat, 0);
    let dw: Vec<f32> = (0..ROWS * COLS)
        .map(|i| ((i % 13) as f32 - 6.0) * 0.01)
        .collect();
    let mut rt = Rng::from_seed(81);
    let mut rf = Rng::from_seed(81);
    for _ in 0..4 {
        grid.analog_update(&dw, &mut rt);
        flat.analog_update(&dw, &mut rf);
    }
    let mut got = vec![0.0f32; grid.len()];
    grid.read_into(0.0, &mut Rng::from_seed(0), &mut got);
    assert_eq!(got, flat.w);
    assert_eq!(grid.pulse_count(), flat.pulse_count);
}

#[test]
fn adc_faults_arm_and_clear_on_the_io_chains() {
    let mut arr = tiled(91);
    let mut plan = FaultPlan::of(1, FaultFamily::Adc, 0.25);
    plan.adc_sat = 1.5;
    arr.arm_faults(&plan);
    for k in 0..arr.n_tiles() {
        assert_eq!(arr.io(k).adc_offset, 0.25);
        assert_eq!(arr.io(k).adc_sat, 1.5);
    }
    // ADC faults touch the periphery only — no cell masks
    assert!(arr.faulty_tiles().is_empty());
    arr.clear_faults();
    for k in 0..arr.n_tiles() {
        assert_eq!(arr.io(k).adc_offset, 0.0);
        assert!(arr.io(k).adc_sat.is_infinite());
    }
}
