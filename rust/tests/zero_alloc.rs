//! Allocation accounting for the optimizer hot path: after construction
//! and warmup, `AnalogOptimizer::step` (and `weights`/`cost`) must not
//! touch the heap for ANY registry method — the batched device engine
//! works in caller-owned and stack scratch buffers only.
//!
//! Verified with a counting global allocator. This binary intentionally
//! holds a single #[test] so no concurrent test can allocate while the
//! hot loop is being counted.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use analog_rider::analog::optimizer::{self, AnalogOptimizer as _};
use analog_rider::device::presets;
use analog_rider::optim::Quadratic;
use analog_rider::util::rng::Rng;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

#[test]
fn no_heap_allocation_per_step_on_any_registry_method() {
    let preset = presets::preset("om").unwrap();
    for name in optimizer::METHODS {
        let mut rng = Rng::from_seed(41);
        let obj = Quadratic::new(64, 1.0, 4.0, 0.3, &mut rng);
        // construction (and residual's ZS stage) may allocate freely
        let mut opt = optimizer::spec(name)
            .unwrap()
            .build(64, &preset, 0.3, 0.1, 0.1, &mut rng);
        for _ in 0..3 {
            opt.step(&obj, &mut rng);
            opt.weights();
        }
        let before = ALLOCS.load(Ordering::Relaxed);
        let mut loss_acc = 0.0;
        for _ in 0..50 {
            loss_acc += opt.step(&obj, &mut rng);
            loss_acc += opt.weights()[0] as f64;
            loss_acc += opt.cost().update_pulses as f64;
        }
        let after = ALLOCS.load(Ordering::Relaxed);
        assert!(loss_acc.is_finite());
        assert_eq!(
            after - before,
            0,
            "{name}: optimizer step path touched the heap"
        );
    }
}
