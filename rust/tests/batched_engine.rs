//! Equivalence tests for the batched pulse-update engine
//! (device/array.rs): the batched `analog_update` against the retained
//! scalar reference path (`analog_update_ref`) on shared inputs, the
//! row-chunked parallel path against the same reference, and the
//! zero-alloc read path against its allocating wrapper.
//!
//! Determinism contract under test (DESIGN.md): with c2c disabled,
//! increments that are exact pulse multiples, unit tau, and a
//! power-of-two dw_min, no random draw influences the result and the
//! reciprocal-multiply arithmetic is exact, so batched / parallel /
//! scalar paths must agree bit-for-bit; with noise on, the batched
//! engine consumes a different RNG stream, so the paths are compared in
//! distribution (mean/variance over >= 10k trials).

use analog_rider::device::{presets, DeviceArray, SoftBounds};
use analog_rider::util::rng::Rng;

/// A tile with noise disabled and a power-of-two granularity, so pulse
/// counts are exact and no stochastic-rounding draw is ever consulted.
fn noise_free_tile(rows: usize, cols: usize, seed: u64) -> DeviceArray {
    let mut rng = Rng::from_seed(seed);
    let mut arr = DeviceArray::sample(rows, cols, &presets::OM, 0.3, 0.2, 0.1, &mut rng);
    arr.c2c = 0.0;
    arr.dw_min = 0.0078125; // 2^-7: k * dw_min round-trips exactly
    arr
}

/// Exact-multiple increment pattern: k in -3..=3 cycling over cells,
/// shifted by `round` so successive rounds exercise different signs.
fn exact_dw(arr: &DeviceArray, round: usize) -> Vec<f32> {
    (0..arr.len())
        .map(|i| ((i + round) % 7) as f32 - 3.0)
        .map(|k| k * arr.dw_min)
        .collect()
}

#[test]
fn batched_update_bit_matches_scalar_ref_noise_free() {
    let mut a = noise_free_tile(16, 16, 1);
    let mut b = a.clone();
    let mut rng_a = Rng::from_seed(2);
    let mut rng_b = Rng::from_seed(3); // different stream: must not matter
    for round in 0..5 {
        let dw = exact_dw(&a, round);
        a.analog_update(&dw, &mut rng_a);
        b.analog_update_ref(&dw, &mut rng_b);
    }
    assert_eq!(a.w, b.w, "noise-free batched update must be bit-exact");
    assert_eq!(a.pulse_count, b.pulse_count);
    assert!(a.pulse_count > 0);
}

#[test]
fn parallel_path_bit_matches_scalar_ref_and_is_deterministic() {
    // 256x256 crosses both parallel-dispatch thresholds (cells >= 2^16,
    // rows > chunk): this runs the row-chunked multi-threaded path.
    let mut a = noise_free_tile(256, 256, 4);
    let mut b = a.clone();
    let mut c = a.clone();
    let mut rng_a = Rng::from_seed(5);
    let mut rng_b = Rng::from_seed(6);
    let mut rng_c = Rng::from_seed(5);
    for round in 0..3 {
        let dw = exact_dw(&a, round);
        a.analog_update(&dw, &mut rng_a);
        b.analog_update_ref(&dw, &mut rng_b);
        c.analog_update(&dw, &mut rng_c);
    }
    assert_eq!(a.w, b.w, "parallel path must be bit-exact when noise-free");
    assert_eq!(a.pulse_count, b.pulse_count);
    // chunk sub-streams make repeat runs identical regardless of
    // thread scheduling
    assert_eq!(a.w, c.w, "parallel path must be run-to-run deterministic");
    assert_eq!(a.pulse_count, c.pulse_count);
}

#[test]
fn stochastic_update_matches_ref_in_distribution() {
    // Sub-granularity increment + c2c noise: the batched engine draws
    // from a different stream than the scalar reference, so compare the
    // first two moments of the post-update weight over many trials.
    let dev = SoftBounds::symmetric();
    let trials = 20_000;
    let run = |batched: bool, seed: u64| -> (f64, f64) {
        let mut rng = Rng::from_seed(seed);
        let (mut s, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..trials {
            let mut arr = DeviceArray::uniform(1, 1, &dev, 0.01, 0.3);
            if batched {
                arr.analog_update(&[0.0037], &mut rng);
            } else {
                arr.analog_update_ref(&[0.0037], &mut rng);
            }
            let w = arr.w[0] as f64;
            s += w;
            s2 += w * w;
        }
        let mean = s / trials as f64;
        (mean, s2 / trials as f64 - mean * mean)
    };
    let (mean_b, var_b) = run(true, 7);
    let (mean_r, var_r) = run(false, 8);
    // E[w] = 0.37 * dw_min = 0.0037 for both; diff SE ~ 5e-5
    assert!(
        (mean_b - mean_r).abs() < 2.5e-4,
        "means diverge: batched {mean_b} vs ref {mean_r}"
    );
    assert!(
        (var_b / var_r - 1.0).abs() < 0.1,
        "variances diverge: batched {var_b} vs ref {var_r}"
    );
}

#[test]
fn pulse_all_bit_matches_scalar_primitive_noise_free() {
    let mut a = noise_free_tile(8, 8, 9);
    let mut b = a.clone();
    let mut rng = Rng::from_seed(10);
    for k in 0..50 {
        let up = k % 2 == 0;
        a.pulse_all(up, &mut rng);
        for i in 0..b.len() {
            b.pulse_cell(i, up, &mut rng);
        }
    }
    assert_eq!(a.w, b.w, "batched pulse cycle must match the scalar primitive");
    assert_eq!(a.pulse_count, b.pulse_count);
}

#[test]
fn read_into_matches_read_and_its_statistics() {
    let mut rng = Rng::from_seed(11);
    let mut arr = DeviceArray::sample(64, 64, &presets::OM, 0.2, 0.1, 0.1, &mut rng);
    for _ in 0..10 {
        arr.pulse_all_random(&mut rng);
    }
    // the allocating wrapper and the zero-alloc path share one stream
    let mut rng_a = Rng::from_seed(12);
    let mut rng_b = Rng::from_seed(12);
    let via_read = arr.read(0.02, &mut rng_a);
    let mut via_into = vec![0.0f32; arr.len()];
    arr.read_into(0.02, &mut rng_b, &mut via_into);
    assert_eq!(via_read, via_into);
    // noiseless read is the exact weight vector (and consumes no draws)
    let mut before = rng_b.clone();
    arr.read_into(0.0, &mut rng_b, &mut via_into);
    assert_eq!(via_into, arr.w);
    assert_eq!(rng_b.next_u32(), before.next_u32());
    // read noise is centred on w with the requested std
    let n = arr.len() as f64;
    let err: Vec<f64> = via_read
        .iter()
        .zip(&arr.w)
        .map(|(r, w)| (r - w) as f64)
        .collect();
    let mean = err.iter().sum::<f64>() / n;
    let var = err.iter().map(|e| (e - mean) * (e - mean)).sum::<f64>() / n;
    assert!(mean.abs() < 2e-3, "{mean}");
    assert!((var.sqrt() - 0.02).abs() < 2e-3, "{}", var.sqrt());
}

#[test]
fn program_stays_exact_on_large_tiles() {
    // programming goes through the batched (and, here, parallel) update
    // path; the closed loop must still land on the target
    let mut rng = Rng::from_seed(13);
    let dev = SoftBounds::from_gamma_rho(1.0, 0.2);
    let mut arr = DeviceArray::uniform(256, 256, &dev, 1e-4, 0.0);
    let target: Vec<f32> = (0..arr.len())
        .map(|i| 0.4 * (((i % 13) as f32 / 6.0) - 1.0))
        .collect();
    for _ in 0..8 {
        arr.program(&target, &mut rng);
    }
    for (w, t) in arr.w.iter().zip(&target) {
        assert!((w - t).abs() < 0.02, "{w} vs {t}");
    }
}
