//! Artifact sweep for the static plan verifier: every module in the
//! registry must compile AND pass independent verification
//! (`runtime::verify`), and the aggregate statistics must look like a
//! real program (steps, fusion, buffer reuse), not a vacuous pass.

use analog_rider::runtime::{verify_hlo_text, Registry, VerifyStats};

fn registry() -> Option<Registry> {
    let dir = Registry::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Registry::load(dir).expect("manifest loads"))
}

#[test]
fn every_artifact_plan_verifies() {
    let Some(reg) = registry() else { return };
    assert!(!reg.artifacts.is_empty(), "registry lists artifacts");
    let mut total = VerifyStats::default();
    for (name, spec) in &reg.artifacts {
        let src = std::fs::read_to_string(&spec.file)
            .unwrap_or_else(|e| panic!("{name}: artifact unreadable: {e}"));
        let st = verify_hlo_text(&src)
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(st.instructions > 0, "{name}: empty module");
        assert!(st.steps > 0, "{name}: no executable steps");
        total.computations += st.computations;
        total.instructions += st.instructions;
        total.steps += st.steps;
        total.groups += st.groups;
        total.members += st.members;
        total.buffers += st.buffers;
        total.buffer_slots += st.buffer_slots;
    }
    // sanity over the whole artifact set: the planner actually fuses
    // (each group holds >= 2 members) and the buffer pool is reused
    assert!(total.groups > 0, "no fusion anywhere in the artifact set");
    assert!(total.members >= 2 * total.groups, "groups below minimum size");
    assert!(total.buffer_slots > total.buffers, "buffer pool never reused");
    assert!(total.reuse_ratio() > 1.0);
}

#[test]
fn verifier_runs_inside_compile_under_env_flag() {
    // RIDER_VERIFY wiring: compiling through the PJRT surface with the
    // flag set must reject nothing on a good artifact (debug builds
    // verify unconditionally; this exercises the same path).
    let Some(reg) = registry() else { return };
    let (name, spec) = reg.artifacts.iter().next().expect("non-empty registry");
    let src = std::fs::read_to_string(&spec.file).expect("artifact readable");
    std::env::set_var("RIDER_VERIFY", "1");
    let client = analog_rider::runtime::xla::PjRtClient::cpu().expect("client");
    let proto = analog_rider::runtime::xla::HloModuleProto::from_text(&src)
        .unwrap_or_else(|e| panic!("{name}: {e}"));
    let comp = analog_rider::runtime::xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .unwrap_or_else(|e| panic!("{name}: compile+verify failed: {e}"));
}
