//! Shared integration-test support: artifact/backend gating and
//! debug-build budget scaling, extracted so the scaling policy cannot
//! drift between suites (each previously carried its own copy).

// each test binary uses its own subset of these helpers
#![allow(dead_code)]

use analog_rider::data::Dataset;
use analog_rider::runtime::{Executor, Registry};

/// Artifact + backend gate: `None` (after an eprintln starting with
/// "skipping:", which `./ci.sh e2e` greps for) when the checked-in
/// artifacts are absent or the XLA backend is stubbed out.
pub fn setup() -> Option<(Executor, Registry)> {
    let dir = Registry::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    // artifacts may exist while the XLA backend is stubbed out
    // (runtime::xla) — that's a skip, not a failure
    let Ok(exec) = Executor::cpu() else {
        eprintln!("skipping: PJRT/XLA backend unavailable in this build");
        return None;
    };
    Some((exec, Registry::load(dir).expect("manifest")))
}

/// The HLO interpreter is ~an order of magnitude slower unoptimized, so
/// debug runs (tier-1 `cargo test -q`) use a reduced budget; release
/// runs (`./ci.sh e2e`) keep the full one.
pub fn budget(debug: usize, release: usize) -> usize {
    if cfg!(debug_assertions) {
        debug
    } else {
        release
    }
}

/// Fixed fcn-shaped batches so two trainer instances can replay the
/// exact same input sequence.
pub fn batches(reg: &Registry, n: usize) -> Vec<(Vec<f32>, Vec<i32>)> {
    let spec = reg.model("fcn").unwrap();
    let ds = Dataset::digits(spec.batch * n, 19);
    (0..n)
        .map(|k| {
            let lo = k * spec.batch;
            (
                ds.x[lo * ds.d..(lo + spec.batch) * ds.d].to_vec(),
                ds.y[lo..lo + spec.batch].to_vec(),
            )
        })
        .collect()
}
