//! Allocation accounting for the chaos layer: applying a compiled
//! fault mask is part of the `analog_update` hot path, so it must not
//! touch the heap — neither when the armed mask is empty (the
//! zero-cost-when-disarmed contract) nor when it pins and drifts real
//! cells (all randomness and allocation happen at arm time).
//!
//! Verified with a counting global allocator. This binary intentionally
//! holds a single #[test] so no concurrent test can allocate while the
//! hot loop is being counted. The array stays below the row-chunked
//! parallel threshold, where the update path is allocation-free.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use analog_rider::device::fault::{FaultFamily, FaultPlan};
use analog_rider::device::{presets, DeviceArray};
use analog_rider::util::rng::Rng;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

#[test]
fn fault_mask_application_never_allocates() {
    let preset = presets::preset("om").unwrap();
    // (label, plan): the armed-but-empty hook and a mask with real work
    let cases: [(&str, FaultPlan); 3] = [
        ("armed-empty", FaultPlan::none(7)),
        ("stuck", FaultPlan::of(11, FaultFamily::StuckAtBound, 0.1)),
        ("drift", FaultPlan::of(13, FaultFamily::DriftToSp, 0.2)),
    ];
    for (label, plan) in cases {
        let mut rng = Rng::from_seed(41);
        let mut arr = DeviceArray::sample(64, 64, &preset, 0.3, 0.1, 0.1, &mut rng);
        // arming may allocate freely (compiles the mask)
        plan.arm_array(&mut arr, 0);
        let dw: Vec<f32> = (0..arr.len())
            .map(|i| ((i % 7) as f32 - 3.0) * 0.02)
            .collect();
        for _ in 0..3 {
            arr.analog_update(&dw, &mut rng);
            arr.analog_update_det(&dw);
        }
        let before = ALLOCS.load(Ordering::Relaxed);
        let mut acc = 0.0f64;
        for _ in 0..50 {
            arr.analog_update(&dw, &mut rng);
            arr.analog_update_det(&dw);
            acc += arr.w[0] as f64;
        }
        let after = ALLOCS.load(Ordering::Relaxed);
        assert!(acc.is_finite());
        assert_eq!(
            after - before,
            0,
            "{label}: faulted analog_update touched the heap"
        );
    }
}
