//! Cross-module property tests on the coordinator invariants
//! (DESIGN.md section 6) using the in-repo prop harness.

use analog_rider::data::{Batcher, Dataset};
use analog_rider::device::{presets, DeviceArray, Response, SoftBounds};
use analog_rider::prop_assert;
use analog_rider::runtime::{ModelSpec, StateLeaf};
use analog_rider::train::fault::{sp_residual_leaves, LossSpikeMonitor};
use analog_rider::train::DevParams;
use analog_rider::util::json::Json;
use analog_rider::util::prop::{self, gen};
use analog_rider::util::rng::Rng;

#[test]
fn prop_batcher_epoch_coverage() {
    prop::check("batcher coverage", 30, |rng| {
        let n = gen::size(rng, 10, 200);
        let batch = gen::size(rng, 1, n);
        let mut b = Batcher::new(n, batch, rng.next_u64());
        let steps = b.steps_per_epoch();
        let mut seen = vec![0u32; n];
        for _ in 0..steps {
            for &i in b.next() {
                seen[i] += 1;
            }
        }
        prop_assert!(
            seen.iter().all(|&c| c <= 1),
            "sample repeated within epoch"
        );
        prop_assert!(
            seen.iter().filter(|&&c| c == 1).count() == steps * batch,
            "wrong coverage count"
        );
        Ok(())
    });
}

#[test]
fn prop_device_weights_bounded_under_any_updates() {
    prop::check("device bounds", 25, |rng| {
        let rows = gen::size(rng, 1, 12);
        let cols = gen::size(rng, 1, 12);
        let mut arr =
            DeviceArray::sample(rows, cols, &presets::OM, 0.3, 0.5, 0.2, rng);
        for _ in 0..40 {
            let dw = gen::vec_uniform_f32(rng, rows * cols, -3.0, 3.0);
            arr.analog_update(&dw, rng);
        }
        prop_assert!(
            arr.w.iter().all(|&w| (-1.0001..=1.0001).contains(&w)),
            "weights escaped the conductance window"
        );
        Ok(())
    });
}

#[test]
fn prop_sp_is_g_root_for_random_devices() {
    prop::check("sp root", 100, |rng| {
        let gamma = rng.uniform_in(0.3, 2.0);
        let rho = rng.uniform_in(-0.8, 0.8) * gamma;
        let d = SoftBounds::from_gamma_rho(gamma, rho);
        let sp = d.symmetric_point();
        prop_assert!(d.g_asym(sp).abs() < 1e-9, "G(sp) = {}", d.g_asym(sp));
        prop_assert!(
            (-1.0..=1.0).contains(&sp),
            "sp {} outside window",
            sp
        );
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip_random_trees() {
    prop::check("json roundtrip", 40, |rng| {
        fn gen_val(rng: &mut Rng, depth: usize) -> Json {
            match if depth == 0 { rng.below(3) } else { rng.below(5) } {
                0 => Json::Num((rng.uniform_in(-1e6, 1e6) * 100.0).round() / 100.0),
                1 => Json::Bool(rng.bernoulli(0.5)),
                2 => Json::Str(format!("s{}", rng.next_u32())),
                3 => Json::Arr((0..rng.below(4)).map(|_| gen_val(rng, depth - 1)).collect()),
                _ => Json::Obj(
                    (0..rng.below(4))
                        .map(|i| (format!("k{i}"), gen_val(rng, depth - 1)))
                        .collect(),
                ),
            }
        }
        let v = gen_val(rng, 3);
        let v2 = Json::parse(&v.dump()).map_err(|e| e.to_string())?;
        prop_assert!(v == v2, "roundtrip mismatch");
        Ok(())
    });
}

#[test]
fn prop_zs_estimate_improves_with_budget() {
    prop::check("zs monotone-ish", 8, |rng| {
        let seed = rng.next_u64();
        let err = |n: u64| {
            let mut r = Rng::new(seed, 1);
            let mut arr =
                DeviceArray::sample(12, 12, &presets::PRECISE, 0.4, 0.1, 0.1, &mut r);
            analog_rider::analog::zs::run(
                &mut arr,
                n,
                analog_rider::analog::zs::ZsVariant::Cyclic,
                &mut r,
            )
            .mean_abs_error()
        };
        prop_assert!(err(4000) < err(40), "budget did not help");
        Ok(())
    });
}

#[test]
fn prop_pulse_counter_additive() {
    prop::check("pulse accounting", 20, |rng| {
        let dev = SoftBounds::symmetric();
        let mut arr = DeviceArray::uniform(4, 4, &dev, 0.01, 0.0);
        let mut expected = 0u64;
        for _ in 0..10 {
            let k = gen::size(rng, 0, 5) as f32;
            let dw = vec![k * 0.01; 16];
            arr.analog_update_det(&dw);
            expected += (k as u64) * 16;
        }
        prop_assert!(
            arr.pulse_count == expected,
            "count {} != expected {}",
            arr.pulse_count,
            expected
        );
        Ok(())
    });
}

#[test]
fn prop_pulse_accounting_is_schedule_invariant() {
    // `DeviceArray::pulse_count` is the source that feeds the
    // `device_pulses_total` counter, so this pins the pipeline's pulse
    // accounting: any legal stage interleaving (per-stage FIFO order
    // preserved, global order arbitrary — exactly what the commit chain
    // guarantees at D = 0) must charge the same total and leave the
    // same weights, bit for bit.
    prop::check("pulse schedule invariance", 20, |rng| {
        let dev = SoftBounds::symmetric();
        let stages = gen::size(rng, 1, 4);
        let steps = gen::size(rng, 2, 8);
        let rows = gen::size(rng, 2, 5);
        let cols = gen::size(rng, 2, 5);
        let dws: Vec<Vec<Vec<f32>>> = (0..stages)
            .map(|_| {
                (0..steps)
                    .map(|_| gen::vec_uniform_f32(rng, rows * cols, -0.05, 0.05))
                    .collect()
            })
            .collect();
        let fresh = || -> Vec<DeviceArray> {
            (0..stages)
                .map(|_| DeviceArray::uniform(rows, cols, &dev, 0.01, 0.0))
                .collect()
        };

        // oracle: the synchronous order (microbatch-major, stages inner)
        let mut serial = fresh();
        for k in 0..steps {
            for s in 0..stages {
                serial[s].analog_update_det(&dws[s][k]);
            }
        }

        // random legal interleaving over identical arrays
        let mut inter = fresh();
        let mut next = vec![0usize; stages];
        let mut remaining = stages * steps;
        while remaining > 0 {
            let s = rng.below(stages);
            if next[s] < steps {
                inter[s].analog_update_det(&dws[s][next[s]]);
                next[s] += 1;
                remaining -= 1;
            }
        }

        let ts: u64 = serial.iter().map(|a| a.pulse_count).sum();
        let ti: u64 = inter.iter().map(|a| a.pulse_count).sum();
        prop_assert!(ts == ti, "total pulses {} != {}", ts, ti);
        for (s, (a, b)) in serial.iter().zip(&inter).enumerate() {
            prop_assert!(
                a.pulse_count == b.pulse_count,
                "stage {} pulse count {} != {}",
                s,
                a.pulse_count,
                b.pulse_count
            );
            prop_assert!(
                a.w.iter().zip(&b.w).all(|(x, y)| x.to_bits() == y.to_bits()),
                "stage {} weights diverged under reordering",
                s
            );
        }
        Ok(())
    });
}

#[test]
fn prop_loss_spike_monitor_is_commit_order_invariant() {
    // The pipelined coordinator feeds the spike monitor through an
    // in-order reorder buffer: workers complete microbatches in any
    // order, the buffer drains them in step order. The trigger sequence
    // must therefore match a serial fold exactly — including around
    // NaNs and genuine spikes.
    prop::check("spike monitor reorder", 30, |rng| {
        let n = gen::size(rng, 5, 40);
        let losses: Vec<f64> = (0..n)
            .map(|_| match rng.below(10) {
                0 => f64::NAN,
                1 => rng.uniform_in(5.0, 50.0),
                _ => rng.uniform_in(0.1, 2.0),
            })
            .collect();
        let mut mon = LossSpikeMonitor::new(3.0, 2);
        let serial: Vec<bool> = losses.iter().map(|&l| mon.observe(l)).collect();

        // completion order: a random permutation of step indices
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        let mut mon2 = LossSpikeMonitor::new(3.0, 2);
        let mut done = vec![false; n];
        let mut commit = 0usize;
        let mut replay = Vec::with_capacity(n);
        for &k in &order {
            done[k] = true;
            while commit < n && done[commit] {
                replay.push(mon2.observe(losses[commit]));
                commit += 1;
            }
        }
        prop_assert!(commit == n, "reorder buffer failed to drain");
        prop_assert!(serial == replay, "trigger sequence diverged under reordering");
        Ok(())
    });
}

#[test]
fn prop_sp_residual_invariant_under_stage_partition() {
    // The pipelined coordinator probes SP residual from leaves
    // reassembled out of per-stage groups rather than a monolithic
    // `ModelState`; scattering the leaves across a random partition and
    // reassembling in manifest order must not move the probe by a bit.
    prop::check("sp residual partition", 30, |rng| {
        let mut state = Vec::new();
        for t in 0..2usize {
            for role in ["w", "p", "pap", "pam", "q"] {
                state.push(StateLeaf {
                    name: format!("t{t}.{role}"),
                    shape: vec![3, 3],
                    role: role.into(),
                    tile: t,
                });
            }
        }
        state.push(StateLeaf {
            name: "b".into(),
            shape: vec![3],
            role: "bias".into(),
            tile: 0,
        });
        let spec = ModelSpec {
            name: "toy".into(),
            batch: 2,
            eval_batch: 2,
            d_in: 3,
            n_classes: 3,
            state,
        };
        let dev = DevParams::from_preset(&presets::OM);
        let leaves: Vec<Vec<f32>> = spec
            .state
            .iter()
            .map(|l| gen::vec_uniform_f32(rng, l.numel(), -1.0, 1.0))
            .collect();
        let whole = sp_residual_leaves(&spec, &leaves, &dev);

        let stages = gen::size(rng, 1, 4);
        let mut groups: Vec<Vec<(usize, Vec<f32>)>> = vec![Vec::new(); stages];
        for (li, leaf) in leaves.iter().enumerate() {
            groups[rng.below(stages)].push((li, leaf.clone()));
        }
        let mut reassembled = vec![Vec::new(); leaves.len()];
        for g in groups {
            for (li, v) in g {
                reassembled[li] = v;
            }
        }
        let part = sp_residual_leaves(&spec, &reassembled, &dev);
        prop_assert!(
            whole.to_bits() == part.to_bits(),
            "residual {} != {} after partition",
            whole,
            part
        );
        Ok(())
    });
}
