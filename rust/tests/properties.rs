//! Cross-module property tests on the coordinator invariants
//! (DESIGN.md section 6) using the in-repo prop harness.

use analog_rider::data::{Batcher, Dataset};
use analog_rider::device::{presets, DeviceArray, Response, SoftBounds};
use analog_rider::prop_assert;
use analog_rider::util::json::Json;
use analog_rider::util::prop::{self, gen};
use analog_rider::util::rng::Rng;

#[test]
fn prop_batcher_epoch_coverage() {
    prop::check("batcher coverage", 30, |rng| {
        let n = gen::size(rng, 10, 200);
        let batch = gen::size(rng, 1, n);
        let mut b = Batcher::new(n, batch, rng.next_u64());
        let steps = b.steps_per_epoch();
        let mut seen = vec![0u32; n];
        for _ in 0..steps {
            for &i in b.next() {
                seen[i] += 1;
            }
        }
        prop_assert!(
            seen.iter().all(|&c| c <= 1),
            "sample repeated within epoch"
        );
        prop_assert!(
            seen.iter().filter(|&&c| c == 1).count() == steps * batch,
            "wrong coverage count"
        );
        Ok(())
    });
}

#[test]
fn prop_device_weights_bounded_under_any_updates() {
    prop::check("device bounds", 25, |rng| {
        let rows = gen::size(rng, 1, 12);
        let cols = gen::size(rng, 1, 12);
        let mut arr =
            DeviceArray::sample(rows, cols, &presets::OM, 0.3, 0.5, 0.2, rng);
        for _ in 0..40 {
            let dw = gen::vec_uniform_f32(rng, rows * cols, -3.0, 3.0);
            arr.analog_update(&dw, rng);
        }
        prop_assert!(
            arr.w.iter().all(|&w| (-1.0001..=1.0001).contains(&w)),
            "weights escaped the conductance window"
        );
        Ok(())
    });
}

#[test]
fn prop_sp_is_g_root_for_random_devices() {
    prop::check("sp root", 100, |rng| {
        let gamma = rng.uniform_in(0.3, 2.0);
        let rho = rng.uniform_in(-0.8, 0.8) * gamma;
        let d = SoftBounds::from_gamma_rho(gamma, rho);
        let sp = d.symmetric_point();
        prop_assert!(d.g_asym(sp).abs() < 1e-9, "G(sp) = {}", d.g_asym(sp));
        prop_assert!(
            (-1.0..=1.0).contains(&sp),
            "sp {} outside window",
            sp
        );
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip_random_trees() {
    prop::check("json roundtrip", 40, |rng| {
        fn gen_val(rng: &mut Rng, depth: usize) -> Json {
            match if depth == 0 { rng.below(3) } else { rng.below(5) } {
                0 => Json::Num((rng.uniform_in(-1e6, 1e6) * 100.0).round() / 100.0),
                1 => Json::Bool(rng.bernoulli(0.5)),
                2 => Json::Str(format!("s{}", rng.next_u32())),
                3 => Json::Arr((0..rng.below(4)).map(|_| gen_val(rng, depth - 1)).collect()),
                _ => Json::Obj(
                    (0..rng.below(4))
                        .map(|i| (format!("k{i}"), gen_val(rng, depth - 1)))
                        .collect(),
                ),
            }
        }
        let v = gen_val(rng, 3);
        let v2 = Json::parse(&v.dump()).map_err(|e| e.to_string())?;
        prop_assert!(v == v2, "roundtrip mismatch");
        Ok(())
    });
}

#[test]
fn prop_zs_estimate_improves_with_budget() {
    prop::check("zs monotone-ish", 8, |rng| {
        let seed = rng.next_u64();
        let err = |n: u64| {
            let mut r = Rng::new(seed, 1);
            let mut arr =
                DeviceArray::sample(12, 12, &presets::PRECISE, 0.4, 0.1, 0.1, &mut r);
            analog_rider::analog::zs::run(
                &mut arr,
                n,
                analog_rider::analog::zs::ZsVariant::Cyclic,
                &mut r,
            )
            .mean_abs_error()
        };
        prop_assert!(err(4000) < err(40), "budget did not help");
        Ok(())
    });
}

#[test]
fn prop_pulse_counter_additive() {
    prop::check("pulse accounting", 20, |rng| {
        let dev = SoftBounds::symmetric();
        let mut arr = DeviceArray::uniform(4, 4, &dev, 0.01, 0.0);
        let mut expected = 0u64;
        for _ in 0..10 {
            let k = gen::size(rng, 0, 5) as f32;
            let dw = vec![k * 0.01; 16];
            arr.analog_update_det(&dw);
            expected += (k as u64) * 16;
        }
        prop_assert!(
            arr.pulse_count == expected,
            "count {} != expected {}",
            arr.pulse_count,
            expected
        );
        Ok(())
    });
}
