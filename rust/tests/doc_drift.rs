//! Documentation-drift invariants: the README registry table, the CLI
//! help text, and the `Method` enum must all list exactly the names in
//! `optimizer::METHODS`, in the same order. Adding (or renaming) a
//! method without updating the docs fails this test, not a reader.

use analog_rider::analog::optimizer::{Method, METHODS};

const README: &str = include_str!("../../README.md");
const MAIN_RS: &str = include_str!("../src/main.rs");

/// Names from the README registry table: rows of the form
/// ``| `name` | description |`` (the only table in the README whose
/// first column is backticked).
fn readme_table_names() -> Vec<String> {
    README
        .lines()
        .filter_map(|l| {
            let rest = l.strip_prefix("| `")?;
            let (name, _) = rest.split_once('`')?;
            Some(name.to_string())
        })
        .collect()
}

#[test]
fn readme_registry_table_matches_methods() {
    let got = readme_table_names();
    assert_eq!(
        got, METHODS,
        "README registry table rows must list exactly optimizer::METHODS, in order"
    );
}

#[test]
fn cli_help_lists_every_method() {
    // the help text names the registry inline as `a|b|...):` — rebuild
    // that string from the source of truth and require it verbatim
    let want = format!("{}):", METHODS.join("|"));
    assert!(
        MAIN_RS.contains(&want),
        "rider help text must list the method registry as `{want}`"
    );
}

#[test]
fn method_enum_matches_methods() {
    let got: Vec<&str> = Method::ALL.iter().map(|m| m.name()).collect();
    assert_eq!(
        got, METHODS,
        "Method::ALL and METHODS must stay in lock-step (same names, same order)"
    );
}
