//! Documentation-drift invariants: the README registry table, the CLI
//! help text, and the `Method` enum must all list exactly the names in
//! `optimizer::METHODS`, in the same order — and the METRICS.md key
//! reference must mirror the canonical metric registry
//! (`util::metrics::SPECS`) row for row. Adding (or renaming) a method
//! or a metric without updating the docs fails this test, not a reader.

use analog_rider::analog::optimizer::{Method, METHODS};
use analog_rider::util::metrics::{Kind, REQUIRED_TRACE_KEYS, SPECS};

const README: &str = include_str!("../../README.md");
const MAIN_RS: &str = include_str!("../src/main.rs");
const METRICS_MD: &str = include_str!("../../METRICS.md");
const CI_SH: &str = include_str!("../../ci.sh");

/// Names from the README registry table: rows of the form
/// ``| `name` | description |`` (the only table in the README whose
/// first column is backticked).
fn readme_table_names() -> Vec<String> {
    README
        .lines()
        .filter_map(|l| {
            let rest = l.strip_prefix("| `")?;
            let (name, _) = rest.split_once('`')?;
            Some(name.to_string())
        })
        .collect()
}

#[test]
fn readme_registry_table_matches_methods() {
    let got = readme_table_names();
    assert_eq!(
        got, METHODS,
        "README registry table rows must list exactly optimizer::METHODS, in order"
    );
}

#[test]
fn cli_help_lists_every_method() {
    // the help text names the registry inline as `a|b|...):` — rebuild
    // that string from the source of truth and require it verbatim
    let want = format!("{}):", METHODS.join("|"));
    assert!(
        MAIN_RS.contains(&want),
        "rider help text must list the method registry as `{want}`"
    );
}

#[test]
fn method_enum_matches_methods() {
    let got: Vec<&str> = Method::ALL.iter().map(|m| m.name()).collect();
    assert_eq!(
        got, METHODS,
        "Method::ALL and METHODS must stay in lock-step (same names, same order)"
    );
}

/// The METRICS.md key table must mirror `util::metrics::SPECS` exactly:
/// same rows, same order, every column. The registry is the source of
/// truth; regenerate the table from it when this fails.
#[test]
fn metrics_md_key_table_matches_registry() {
    let rows: Vec<&str> = METRICS_MD
        .lines()
        .filter(|l| l.starts_with("| `"))
        .collect();
    assert_eq!(
        rows.len(),
        SPECS.len(),
        "METRICS.md must document every registered key (one `| `name`` row each)"
    );
    for (row, s) in rows.iter().zip(SPECS) {
        let kind = match s.kind {
            Kind::Counter => "counter",
            Kind::Gauge => "gauge",
            Kind::Histogram => "histogram",
        };
        let want = format!(
            "| `{}` | {} | {} | {} | `{}` | {} |",
            s.name, kind, s.unit, s.labels, s.module, s.help
        );
        assert_eq!(
            *row, want,
            "METRICS.md row for `{}` must mirror util::metrics::SPECS",
            s.name
        );
    }
}

/// Every key the `./ci.sh metrics` smoke stage requires must be a
/// registered, documented series, and the stage itself must assert it
/// by name — the three artifacts cannot drift apart.
#[test]
fn required_trace_keys_are_documented_and_ci_checked() {
    for key in REQUIRED_TRACE_KEYS {
        assert!(
            SPECS.iter().any(|s| s.name == *key),
            "required trace key {key} is not in the registry"
        );
        assert!(
            METRICS_MD.contains(&format!("`{key}`")),
            "METRICS.md must document required trace key {key}"
        );
        assert!(
            CI_SH.contains(&format!("\"{key}\"")),
            "the ci.sh metrics stage must assert required trace key {key}"
        );
    }
}
