//! End-to-end trainer integration: the full Rust->PJRT->artifact loop
//! must reduce training loss on the synthetic digits within a small
//! budget, and the two-stage ZS path must calibrate the reference.

mod common;

use analog_rider::data::Dataset;
use analog_rider::train::{TrainConfig, Trainer};
use common::{budget, setup};

#[test]
fn erider_reduces_loss_on_digits() {
    let Some((exec, reg)) = setup() else { return };
    let train = Dataset::digits(budget(64, 320), 11);
    let test = Dataset::digits(200, 12);
    let mut cfg = TrainConfig::by_name("fcn", "erider").expect("registry name");
    cfg.steps = budget(20, 80);
    cfg.ref_mean = 0.3;
    cfg.ref_std = 0.2;
    cfg.seed = 5;
    let mut t = Trainer::new(&exec, &reg, cfg).expect("trainer");
    let res = t.train(&train, Some(&test)).expect("train");
    // Pipeline-mechanics check: losses stay finite and bounded (analog
    // training at this step budget is noisy; the accuracy claims are
    // validated at experiment scale, see EXPERIMENTS.md).
    let tail = res.final_loss(20);
    assert!(tail.is_finite() && tail < 2.0 * res.losses[0],
            "unstable: head {} tail {tail}", res.losses[0]);
    assert!(res.final_eval_acc >= 0.0);
    assert!(res.cost.update_pulses > 0);
}

#[test]
fn zs_calibration_sets_reference() {
    let Some((exec, reg)) = setup() else { return };
    let mut cfg = TrainConfig::by_name("fcn", "ttv2").expect("registry name");
    cfg.steps = 1;
    cfg.ref_mean = 0.4;
    cfg.ref_std = 0.1;
    cfg.zs_pulses = budget(150, 400) as u64;
    cfg.dev.dw_min = 0.02;
    cfg.dev.sigma_c2c = 0.0;
    let mut t = Trainer::new(&exec, &reg, cfg).expect("trainer");
    // after ZS, q leaves should be near the P-device SP distribution
    // (mean approx 0.4), not zero.
    let spec = reg.model("fcn").unwrap();
    let q_mean = {
        let idx = analog_rider::train::ModelState::role_indices(spec, "q");
        let mut s = 0.0f64;
        let mut n = 0usize;
        for i in idx {
            s += t.state.leaves[i].iter().map(|&v| v as f64).sum::<f64>();
            n += t.state.leaves[i].len();
        }
        s / n as f64
    };
    assert!(q_mean > 0.2, "q mean {q_mean}, ZS calibration had no effect");

    // the calibration cost paid in Trainer::new must surface in the
    // train result (it used to be computed and thrown away)
    let zs = t.cfg.zs_pulses;
    let train = Dataset::digits(64, 13);
    let res = t.train(&train, None).expect("train");
    let nw = spec.n_weights() as u64;
    assert_eq!(res.cost.calibration_pulses, zs * nw);
    assert!(res.cost.update_pulses > 0);
}

#[test]
fn eval_handles_small_and_ragged_datasets() {
    // Regression: eval used to slice out of range (panic) when
    // n < eval_batch and silently drop the remainder when
    // n % eval_batch != 0.
    let Some((exec, reg)) = setup() else { return };
    let spec = reg.model("fcn").unwrap();
    let eb = spec.eval_batch;
    let mut cfg = TrainConfig::by_name("fcn", "erider").expect("registry name");
    cfg.seed = 3;
    let mut t = Trainer::new(&exec, &reg, cfg).expect("trainer");
    // n < eval_batch; n % eval_batch != 0 (full batches + a partial
    // tail); and an exact multiple (the unchanged fast path)
    for n in [eb / 2 + 3, 2 * eb + eb / 3, 2 * eb] {
        let ds = Dataset::digits(n, 41);
        let (loss, acc) = t.eval(&ds).expect("eval");
        assert!(loss.is_finite(), "n={n}: loss {loss}");
        assert!((0.0..=100.0).contains(&acc), "n={n}: acc {acc}");
    }
}

#[test]
fn digital_pretrain_then_deploy() {
    // Table 8 protocol mechanics: digital pre-training reduces loss, and
    // deploying its weights into an analog state transfers them.
    let Some((exec, reg)) = setup() else { return };
    let train = Dataset::digits(budget(64, 320), 21);
    let mut cfg = TrainConfig::by_name("fcn", "digital").expect("registry name");
    cfg.steps = budget(60, 200);
    cfg.seed = 9;
    cfg.hypers.lr_digital = 0.3;
    let mut t = Trainer::new(&exec, &reg, cfg).expect("trainer");
    let res = t.train(&train, None).expect("train");
    assert!(res.final_loss(20) < 0.8 * res.losses[0]);

    let spec = reg.model("fcn").unwrap();
    let mut cfg2 = TrainConfig::by_name("fcn", "erider").expect("registry name");
    cfg2.ref_mean = 0.2;
    cfg2.seed = 10;
    let mut t2 = Trainer::new(&exec, &reg, cfg2).expect("trainer2");
    t2.state.deploy_weights_from(spec, &t.state);
    let widx = analog_rider::train::ModelState::role_indices(spec, "w");
    for i in widx {
        assert_eq!(t.state.leaves[i], t2.state.leaves[i]);
    }
}
