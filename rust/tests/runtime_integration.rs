//! Integration: load real artifacts, compile, execute, check shapes and
//! basic training semantics through the full PJRT path.

use analog_rider::runtime::{Executor, HostTensor, Registry};
use analog_rider::util::rng::Rng;

fn registry() -> Option<Registry> {
    let dir = Registry::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Registry::load(dir).expect("manifest loads"))
}

#[test]
fn manifest_covers_all_models_and_algos() {
    let Some(reg) = registry() else { return };
    for m in ["fcn", "lenet", "convnet3"] {
        assert!(reg.models.contains_key(m), "{m}");
        for a in ["init", "eval", "eval_digital", "zs"] {
            assert!(reg.artifacts.contains_key(&format!("{m}_{a}")), "{m}_{a}");
        }
        for algo in ["sgd", "ttv1", "ttv2", "agad", "erider", "digital"] {
            let name = format!("{m}_step_{algo}");
            assert!(reg.artifacts.contains_key(&name), "{name}");
        }
    }
}

#[test]
fn init_step_eval_roundtrip_fcn() {
    let Some(reg) = registry() else { return };
    // artifacts may exist while the XLA backend is stubbed out
    // (runtime::xla) — that's a skip, not a failure
    let Ok(exec) = Executor::cpu() else {
        eprintln!("skipping: PJRT/XLA backend unavailable in this build");
        return;
    };
    let m = reg.model("fcn").unwrap();

    // init
    let init = reg.artifact("fcn_init").unwrap();
    let state = exec
        .run(
            init,
            &[
                HostTensor::U32(vec![1, 2]),
                HostTensor::F32(vec![0.3, 0.2, 0.1]), // ref_mean, ref_std, sigma_gamma
            ],
        )
        .expect("init runs");
    assert_eq!(state.len(), m.state.len());
    for (leaf, out) in m.state.iter().zip(&state) {
        assert_eq!(leaf.numel(), out.len(), "{}", leaf.name);
    }

    // one erider step with a random batch
    let step = reg.artifact("fcn_step_erider").unwrap();
    let mut rng = Rng::from_seed(7);
    let mut x = vec![0.0f32; m.batch * m.d_in];
    rng.fill_uniform_f32(&mut x);
    let labels: Vec<i32> = (0..m.batch as i32).map(|i| i % 10).collect();
    let mut hypers = vec![0.0f32; reg.n_hypers];
    hypers[reg.hyper_index["lr_fast"]] = 0.1;
    hypers[reg.hyper_index["lr_transfer"]] = 0.05;
    hypers[reg.hyper_index["eta"]] = 0.01;
    hypers[reg.hyper_index["gamma"]] = 0.1;
    hypers[reg.hyper_index["flip_p"]] = 0.1;
    hypers[reg.hyper_index["thresh"]] = 0.1;
    hypers[reg.hyper_index["lr_digital"]] = 0.05;
    hypers[reg.hyper_index["read_noise"]] = 0.01;
    let mut dev = vec![0.0f32; reg.n_dev];
    dev[reg.dev_index["dw_min"]] = 0.01;
    dev[reg.dev_index["sigma_c2c"]] = 0.1;
    dev[reg.dev_index["tau_max"]] = 1.0;
    dev[reg.dev_index["tau_min"]] = 1.0;
    dev[reg.dev_index["out_noise"]] = 0.06;
    dev[reg.dev_index["inp_res"]] = 1.0 / 127.0;
    dev[reg.dev_index["out_res"]] = 1.0 / 511.0;
    dev[reg.dev_index["out_bound"]] = 12.0;

    let mut inputs: Vec<HostTensor> = state.iter().map(|v| HostTensor::F32(v.clone())).collect();
    inputs.push(HostTensor::F32(x.clone()));
    inputs.push(HostTensor::I32(labels.clone()));
    inputs.push(HostTensor::U32(vec![0, 42]));
    inputs.push(HostTensor::F32(hypers.clone()));
    inputs.push(HostTensor::F32(dev.clone()));
    let out = exec.run(step, &inputs).expect("step runs");
    assert_eq!(out.len(), m.state.len() + 1);
    let loss = out.last().unwrap()[0];
    assert!(loss.is_finite() && loss > 0.0, "loss {loss}");

    // state must actually change (the P array moved)
    let p_idx = m.state.iter().position(|l| l.role == "p").unwrap();
    let moved = state[p_idx]
        .iter()
        .zip(&out[p_idx])
        .any(|(a, b)| (a - b).abs() > 1e-7);
    assert!(moved, "P array did not move");

    // eval artifact
    let eval = reg.artifact("fcn_eval").unwrap();
    let eb = m.eval_batch;
    let mut ex = vec![0.0f32; eb * m.d_in];
    rng.fill_uniform_f32(&mut ex);
    let ey: Vec<i32> = (0..eb as i32).map(|i| i % 10).collect();
    let mut einputs: Vec<HostTensor> =
        out[..m.state.len()].iter().map(|v| HostTensor::F32(v.clone())).collect();
    einputs.push(HostTensor::F32(ex));
    einputs.push(HostTensor::I32(ey));
    einputs.push(HostTensor::U32(vec![0, 1]));
    einputs.push(HostTensor::F32(hypers));
    einputs.push(HostTensor::F32(dev));
    let eout = exec.run(eval, &einputs).expect("eval runs");
    assert_eq!(eout.len(), 2);
    let ncorrect = eout[1][0];
    assert!((0.0..=eb as f32).contains(&ncorrect), "ncorrect {ncorrect}");

    // compile cache: init + step + eval
    assert_eq!(exec.cached_count(), 3);
}

#[test]
fn parity_rust_device_vs_jax_kernels() {
    // artifacts/parity.json: deterministic vectors from kernels/ref.py;
    // the Rust substrate must match within f32 tolerance.
    use analog_rider::device::{DeviceArray, IoChain, SoftBounds};
    use analog_rider::util::json::Json;

    let dir = Registry::default_dir();
    let path = dir.join("parity.json");
    if !path.exists() {
        eprintln!("skipping: parity.json not built");
        return;
    }
    let j = Json::parse(&std::fs::read_to_string(path).unwrap()).unwrap();
    let cases = j.get("cases").unwrap().as_arr().unwrap();
    let mut n_pulse = 0;
    let mut n_mvm = 0;
    for c in cases {
        match c.get("kind").unwrap().as_str().unwrap() {
            "pulse_update" => {
                n_pulse += 1;
                let rows = c.get("rows").unwrap().as_usize().unwrap();
                let cols = c.get("cols").unwrap().as_usize().unwrap();
                let dw_min = c.get("dw_min").unwrap().as_f64().unwrap();
                let w = c.get("w").unwrap().as_f32_vec().unwrap();
                let dw = c.get("dw").unwrap().as_f32_vec().unwrap();
                let ap = c.get("alpha_p").unwrap().as_f32_vec().unwrap();
                let am = c.get("alpha_m").unwrap().as_f32_vec().unwrap();
                let expected = c.get("expected").unwrap().as_f32_vec().unwrap();
                let mut arr =
                    DeviceArray::uniform(rows, cols, &SoftBounds::symmetric(), dw_min, 0.0);
                arr.w = w;
                arr.alpha_p = ap;
                arr.alpha_m = am;
                arr.analog_update_det(&dw);
                for (i, (got, want)) in arr.w.iter().zip(&expected).enumerate() {
                    assert!(
                        (got - want).abs() < 1e-5,
                        "pulse case cell {i}: {got} vs {want}"
                    );
                }
            }
            "analog_mvm" => {
                n_mvm += 1;
                let b = c.get("b").unwrap().as_usize().unwrap();
                let k = c.get("k").unwrap().as_usize().unwrap();
                let n = c.get("n").unwrap().as_usize().unwrap();
                let x = c.get("x").unwrap().as_f32_vec().unwrap();
                let w = c.get("w").unwrap().as_f32_vec().unwrap();
                let expected = c.get("expected").unwrap().as_f32_vec().unwrap();
                let io = IoChain::default();
                let mut rng = Rng::from_seed(0);
                let y = io.mvm(&x, &w, b, k, n, &mut rng, true);
                for (i, (got, want)) in y.iter().zip(&expected).enumerate() {
                    assert!(
                        (got - want).abs() < 2e-3,
                        "mvm case element {i}: {got} vs {want}"
                    );
                }
            }
            other => panic!("unknown parity kind {other}"),
        }
    }
    assert!(n_pulse >= 3 && n_mvm >= 2);
}
