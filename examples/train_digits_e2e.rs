//! END-TO-END DRIVER (DESIGN.md section 5): the full three-layer stack on a
//! real small workload. Rust renders the digit corpus, loads the AOT
//! artifacts (Pallas kernels -> JAX model -> HLO text), and trains the
//! analog FCN with E-RIDER under a non-ideal reference for several
//! hundred steps, logging the loss curve, periodic test accuracy and the
//! pulse accounting. Results are recorded in EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example train_digits_e2e [steps]`

use analog_rider::coordinator::RunDir;
use analog_rider::data::Dataset;
use analog_rider::runtime::{Executor, Registry};
use analog_rider::train::{TrainConfig, Trainer};

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(600);
    let reg = Registry::load(Registry::default_dir())?;
    let exec = Executor::cpu()?;

    let train = Dataset::digits(640, 100);
    let test = Dataset::digits(200, 101);

    let mut cfg = TrainConfig::by_name("fcn", "erider")?;
    cfg.steps = steps;
    cfg.eval_every = 100;
    cfg.ref_mean = 0.4; // strongly non-ideal reference
    cfg.ref_std = 0.2;
    cfg.seed = 2026;
    cfg.log = true;

    println!(
        "e2e: model fcn / E-RIDER, {} train samples, {} steps, ref SP ~ N(0.4, 0.2)",
        train.n, steps
    );
    let mut t = Trainer::new(&exec, &reg, cfg)?;
    let res = t.train(&train, Some(&test))?;

    let rd = RunDir::create("e2e_digits")?;
    rd.write_curve("loss", &res.losses)?;
    println!("\n== e2e summary ==");
    println!("steps run        : {}", res.steps_run);
    println!("loss first/last  : {:.4} / {:.4}", res.losses[0], res.final_loss(30));
    for (s, l, a) in &res.evals {
        println!("eval @ step {s:5}: loss {l:.4}  acc {a:.2}%");
    }
    println!("update pulses    : {}", res.cost.update_pulses);
    println!("loss curve       : runs/e2e_digits/loss.csv");
    Ok(())
}
