//! Mini Table-2-style robustness sweep: TT-v2 vs E-RIDER on the analog
//! FCN across reference (SP) offsets, three seeds.
//!
//! Run: `cargo run --release --example robustness_sweep` (needs artifacts).

use analog_rider::coordinator::experiments::training::{robustness_grid, ExpCtx};
use analog_rider::runtime::{Executor, Registry};

fn main() -> anyhow::Result<()> {
    let reg = Registry::load(Registry::default_dir())?;
    let exec = Executor::cpu()?;
    let ctx = ExpCtx {
        exec: &exec,
        reg: &reg,
        steps: 300,
        seeds: vec![1, 2],
    };
    let t = robustness_grid(
        &ctx,
        "robustness_example",
        "fcn",
        &["ttv2", "erider"],
        &[0.0, 0.4],
        &[0.1, 0.4],
        None,
    )?;
    print!("{}", t.render());
    Ok(())
}
