//! SP-calibration deep dive (the Fig. 1 scenario): sweep the ZS pulse
//! budget and the device granularity, printing the accuracy/cost
//! trade-off and the device-dilemma slope of Theorem 2.2.

use analog_rider::analog::zs::{self, ZsVariant};
use analog_rider::device::{presets, DeviceArray};
use analog_rider::util::rng::Rng;
use analog_rider::util::stats;

fn main() {
    println!("== offsets vs pulse budget (64x64, dw_min 1e-3) ==");
    for n in [250u64, 1000, 4000] {
        let mut rng = Rng::new(3, n);
        let mut arr = DeviceArray::sample(64, 64, &presets::PRECISE, 0.4, 0.2, 0.1, &mut rng);
        let res = zs::run(&mut arr, n, ZsVariant::Cyclic, &mut rng);
        println!(
            "  N={n:<6} mean offset {:+.4}  std offset {:+.4}  per-cell |err| {:.4}",
            res.mean_offset(),
            res.std_offset(),
            res.mean_abs_error()
        );
    }

    println!("== device dilemma: pulses for <=2% rel error vs dw_min ==");
    let schedule: Vec<u64> = (0..14).map(|i| 100u64 << i).collect();
    let (mut xs, mut ys) = (Vec::new(), Vec::new());
    for dwm in [4e-3, 2e-3, 1e-3, 5e-4] {
        let mk = |rng: &mut Rng| {
            let mut p = presets::PRECISE.clone();
            p.dw_min = dwm;
            DeviceArray::sample(48, 48, &p, 0.4, 0.2, 0.1, rng)
        };
        if let Some((n, err)) = zs::pulses_to_target(mk, 0.02, &schedule, ZsVariant::Cyclic, 5) {
            println!("  dw_min={dwm:.0e}: N={n} (err {:.2}%)", 100.0 * err);
            xs.push(dwm);
            ys.push(n as f64);
        }
    }
    if xs.len() >= 3 {
        println!(
            "  log-log slope: {:.2}  (Theorem 2.2 predicts ~ -1)",
            stats::loglog_slope(&xs, &ys)
        );
    }
}
