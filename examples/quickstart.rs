//! Quickstart: calibrate a device array with zero-shifting, train at
//! pulse level with a registry method picked by name, then train a small
//! analog FCN with E-RIDER on the synthetic digits — the three core
//! capabilities of the library in ~60 lines.
//!
//! Run: `cargo run --release --example quickstart [-- <method>]`
//! (NN stage needs `make artifacts`; <method> is a registry name:
//! sgd|ttv1|ttv2|agad|residual|rider|erider|digital, default erider).

use analog_rider::analog::optimizer::{self, AnalogOptimizer as _};
use analog_rider::analog::zs::{self, ZsVariant};
use analog_rider::data::Dataset;
use analog_rider::device::{presets, DeviceArray};
use analog_rider::optim::Quadratic;
use analog_rider::runtime::{Executor, Registry};
use analog_rider::train::{TrainConfig, Trainer};
use analog_rider::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // 1. pulse-level: estimate the symmetric points of a 64x64 ReRAM tile
    let mut rng = Rng::from_seed(1);
    let mut arr = DeviceArray::sample(64, 64, &presets::PRECISE, 0.4, 0.2, 0.1, &mut rng);
    let res = zs::run(&mut arr, 2000, ZsVariant::Cyclic, &mut rng);
    println!(
        "ZS calibration: rel. mean error {:.2}% after {} pulses",
        100.0 * res.rel_mean_error(),
        res.pulses
    );

    // 2. pulse-level training through the registry: any method name maps
    //    to a spec whose `build` returns a Box<dyn AnalogOptimizer>.
    let method = std::env::args().nth(1).unwrap_or_else(|| "erider".into());
    let spec = optimizer::spec_or_err(&method).map_err(|e| anyhow::anyhow!(e))?;
    let obj = Quadratic::new(16, 1.0, 4.0, 0.3, &mut rng);
    let mut opt = spec.build(16, &presets::OM, 0.4, 0.1, 0.2, &mut rng);
    let first = opt.step(&obj, &mut rng);
    let mut last = first;
    for _ in 1..3000 {
        last = opt.step(&obj, &mut rng);
    }
    let cost = opt.cost();
    println!(
        "{}: quadratic loss {:.3} -> {:.3} in 3000 steps \
         ({} update pulses, {} calib pulses)",
        opt.name(),
        first,
        last,
        cost.update_pulses,
        cost.calibration_pulses
    );

    // 3. NN-level: train the analog FCN with E-RIDER through the AOT
    //    artifacts (Python is not involved at this point). Needs `make
    //    artifacts` and a real PJRT backend — skip gracefully otherwise.
    let reg = match Registry::load(Registry::default_dir()) {
        Ok(r) => r,
        Err(e) => {
            println!("NN stage skipped (artifacts not built): {e:#}");
            return Ok(());
        }
    };
    let exec = match Executor::cpu() {
        Ok(e) => e,
        Err(e) => {
            println!("NN stage skipped (no PJRT backend): {e:#}");
            return Ok(());
        }
    };
    let mut cfg = TrainConfig::by_name("fcn", "erider")?;
    cfg.steps = 200;
    cfg.ref_mean = 0.4; // non-ideal reference: SPs centred at +0.4
    cfg.ref_std = 0.2;
    cfg.log = true;
    let train = Dataset::digits(320, 7);
    let test = Dataset::digits(200, 8);
    let mut t = Trainer::new(&exec, &reg, cfg)?;
    let r = t.train(&train, Some(&test))?;
    println!(
        "E-RIDER: loss {:.3} -> {:.3}, test acc {:.1}%",
        r.losses[0],
        r.final_loss(20),
        r.final_eval_acc
    );
    Ok(())
}
